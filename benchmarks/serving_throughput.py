"""Serving throughput: continuous batching vs chunked static batching.

A synthetic mixed-acceptance workload is served two ways and timed:

  workload    analytic GMM mean oracle + a DiT-sized tanh-MLP compute
              ballast (so the per-round model call dominates host dispatch,
              as it would for a real denoiser), plus a per-request
              conditioning scalar that perturbs the oracle — high-cond
              chains reject more speculations and run many more rounds than
              low-cond chains (rounds spread roughly 9..18 at K=64).
  chunked     requests padded into fixed batches; each batch is the fused
              batched-ASD program (``asd_sample`` under vmap) running to its
              *slowest* chain, padded lanes burning compute.
  continuous  the slot engine (repro/serving): one speculation round per
              iteration across all slots, finished chains retire at round
              boundaries, slots refill from the queue.

Both engines run the identical model, schedule, and theta (same per-request
keys => bit-identical samples, asserted).  Compile time is excluded via
warmup; walls are best-of ``--repeats``.  Emits JSON (stdout +
results/serving_throughput.json): continuous batching must meet or beat
chunked in samples/sec.

    PYTHONPATH=src:. python benchmarks/serving_throughput.py [--requests 48]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import asd_sample, default_gmm, sl_mean_fn, sl_uniform
from repro.serving.engine import ContinuousASDEngine, Request


def make_synthetic_model(d: int, key, width: int = 1024, depth: int = 8):
    """(params, factory): GMM posterior mean + flops ballast + cond-scaled
    oracle perturbation; ``factory(params, cond) -> model_fn``.

    The ballast contributes an O(1e-6) output so XLA cannot fold it away.
    The cond term bends the oracle as a function of y: chains with larger
    cond see less self-consistent proposals and reject more speculations —
    the mixed-acceptance axis of the workload.  Weights are a params pytree
    (jit argument, not closure constant) in BOTH engines, so neither pays
    the per-dispatch constant-processing tax.
    """
    gmm = default_gmm(d=d)
    base = sl_mean_fn(gmm)
    ks = jax.random.split(key, depth + 3)
    params = {
        "w_in": jax.random.normal(ks[0], (d, width)) / np.sqrt(d),
        "ws": [jax.random.normal(k, (width, width)) / np.sqrt(width)
               for k in ks[1:-2]],
        "w_out": jax.random.normal(ks[-2], (width, d)) / np.sqrt(width),
        "w_bend": jax.random.normal(ks[-1], (d, d)) / np.sqrt(d),
    }

    def factory(p, cond):
        c = 0.0 if cond is None else cond[0]

        def model_fn(t, y):
            g = base(t, y) + c * jnp.tanh(y @ p["w_bend"])
            h = jnp.tanh(y @ p["w_in"])
            for w in p["ws"]:
                h = jnp.tanh(h @ w)
            return g + 1e-6 * (h @ p["w_out"])

        return model_fn

    return params, factory


def run_chunked(params, factory, sched, reqs, theta, batch, d, repeats):
    """Static batching: pad each chunk to ``batch`` fused lanes."""
    fn = jax.jit(jax.vmap(
        lambda y0, k, c, p: (lambda r: (r.sample, r.rounds, r.head_calls))(
            asd_sample(factory(p, c), sched, y0, k, theta, eager_head=True,
                       keep_trajectory=False)),
        in_axes=(0, 0, 0, None),
    ))
    fn_p = lambda y0, k, c: fn(y0, k, c, params)
    pad_y0 = jnp.zeros((batch, d))
    pad_keys = jax.random.split(jax.random.PRNGKey(10**6), batch)
    pad_conds = jnp.zeros((batch, 1))
    jax.block_until_ready(fn_p(pad_y0, pad_keys, pad_conds))  # compile (excluded)

    def one_pass():
        out, rounds_total, heads_total = {}, 0, 0
        for i in range(0, len(reqs), batch):
            chunk = reqs[i:i + batch]
            keys = np.array(pad_keys)
            conds = np.zeros((batch, 1), np.float32)
            for j, r in enumerate(chunk):
                keys[j] = np.asarray(r.key)
                conds[j] = r.cond
            samples, rounds, heads = jax.block_until_ready(
                fn_p(pad_y0, jnp.asarray(keys), jnp.asarray(conds)))
            # the fused batch is paced by its slowest chain
            rounds_total += int(np.max(np.asarray(rounds)))
            heads_total += int(np.max(np.asarray(heads)))
            for j, r in enumerate(chunk):
                out[r.rid] = np.asarray(samples[j])
        return out, rounds_total, heads_total

    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out, rounds_total, heads_total = one_pass()
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    return out, dict(
        engine="chunked-static",
        wall_time_s=wall,
        samples_per_s=len(reqs) / wall,
        fused_rounds=rounds_total,
        head_calls=heads_total,
        batches=int(np.ceil(len(reqs) / batch)),
    )


def run_continuous(params, factory, sched, reqs, theta, slots, d, repeats):
    def build():
        return ContinuousASDEngine(
            model_fn_factory=factory,
            schedule=sched,
            event_shape=(d,),
            num_slots=slots,
            theta=theta,
            d_cond=1,
            eager_head=True,
            keep_trajectory=False,
            params=params,
        )

    # warmup engine (compile round/admit programs), excluded from timing
    warm = build()
    warm.serve([Request(-1 - i, key=jax.random.PRNGKey(10**6 + i),
                        cond=np.zeros((1,), np.float32)) for i in range(slots)])

    best = None
    for _ in range(repeats):
        eng = build()
        eng._round_fn = warm._round_fn
        eng._admit_fn = warm._admit_fn
        eng._peek_fn = warm._peek_fn
        t0 = time.perf_counter()
        out = eng.serve(list(reqs))
        wall = time.perf_counter() - t0
        if best is None or wall < best[0]:
            best = (wall, out, eng.stats)
    wall, out, s = best
    return out, dict(
        engine="continuous",
        wall_time_s=wall,
        samples_per_s=s.retired / wall,
        fused_rounds=s.rounds_total,
        head_calls=s.head_calls_total,
        accept_rate=s.accept_rate(),
        mean_queue_latency_s=s.mean_queue_latency(),
        slots=slots,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=16,
                    help="slots == chunked batch size (same device budget)")
    ap.add_argument("--theta", type=int, default=8)
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--cond-max", type=float, default=4.0,
                    help="max oracle perturbation (acceptance spread)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="results/serving_throughput.json")
    args = ap.parse_args()

    params, factory = make_synthetic_model(args.d, jax.random.PRNGKey(7))
    sched = sl_uniform(K=args.K, t_max=25.0)
    # conds shuffled across arrival order: every chunked batch contains both
    # fast (low-cond) and slow (high-cond) chains, as real traffic would
    ladder = np.linspace(0.0, args.cond_max, args.requests, dtype=np.float32)
    conds = np.random.default_rng(args.seed).permutation(ladder)
    reqs = [
        Request(i, key=jax.random.PRNGKey(args.seed * 10000 + i),
                cond=conds[i : i + 1], y0=np.zeros((args.d,), np.float32))
        for i in range(args.requests)
    ]

    out_c, cont = run_continuous(params, factory, sched, reqs, args.theta,
                                 args.slots, args.d, args.repeats)
    out_s, chunk = run_chunked(params, factory, sched, reqs, args.theta,
                               args.slots, args.d, args.repeats)
    assert len(out_c) == len(out_s) == args.requests
    # identical per-request law: same keys => bit-identical samples
    for r in reqs:
        np.testing.assert_array_equal(out_c[r.rid], out_s[r.rid])

    report = {
        "workload": {
            "requests": args.requests,
            "slots": args.slots,
            "theta": args.theta,
            "K": args.K,
            "d": args.d,
            "cond_max": args.cond_max,
            "model": "gmm-posterior-mean + cond-bend + 8x1024 tanh ballast",
        },
        "chunked": chunk,
        "continuous": cont,
        "throughput_ratio": cont["samples_per_s"] / chunk["samples_per_s"],
        "rounds_saved": chunk["fused_rounds"] - cont["fused_rounds"],
    }
    print(json.dumps(report, indent=2))
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"\ncontinuous/chunked samples-per-sec ratio: "
          f"{report['throughput_ratio']:.2f}x "
          f"({cont['fused_rounds']} vs {chunk['fused_rounds']} fused rounds)")


if __name__ == "__main__":
    main()

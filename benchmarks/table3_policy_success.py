"""Paper Table 3 (Robomimic success rates): the diffusion policy sampled
with ASD-theta succeeds at the same rate as with sequential DDPM.  Offline
stand-in: the 2-D reach task (repro.data.pipeline.RobotReach)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.data.pipeline import RobotReach

K = 100
THETAS = [8, 16, 24, K]
N_EPISODES = 96


def run(quick: bool = False):
    params, dc, data = common.get_trained("policy")
    thetas = [8, K] if quick else THETAS
    n = 32 if quick else N_EPISODES
    sched = common.bench_schedule(K)
    _, obs = data.batch_at(555)
    obs = jnp.asarray(obs[:n])
    rows = []

    acts = common.final_x(
        common.run_sequential(params, dc, sched, n, jax.random.PRNGKey(0), obs)
    )
    succ_ddpm = float(np.mean(np.asarray(RobotReach.success(jnp.asarray(acts), obs))))
    rows.append({
        "name": "tab3_success_ddpm",
        "success_rate": succ_ddpm,
        "us_per_call": 0.0,
        "derived": succ_ddpm,
    })
    for theta in thetas:
        res = common.run_asd(params, dc, sched, theta, n, jax.random.PRNGKey(1), obs)
        acts = common.final_x(res.sample)
        succ = float(np.mean(np.asarray(RobotReach.success(jnp.asarray(acts), obs))))
        rows.append({
            "name": f"tab3_success_theta{theta if theta < K else 'inf'}",
            "success_rate": succ,
            "us_per_call": 0.0,
            "derived": succ,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

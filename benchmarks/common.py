"""Shared benchmark substrate: small trained denoisers (disk-cached) and
speedup measurement helpers.

The paper's experiments run pretrained StableDiffusion/LSUN/Robomimic
models; offline stand-ins are small DiT denoisers trained on the synthetic
pipelines (DESIGN.md §9.3).  Wall-clock numbers on this 1-core CPU container
cannot show *parallel* speedup — the headline metric is the paper's own
*algorithmic* speedup (sequential model-call depth), wall-clock is reported
for completeness.
"""

from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import manager as ckpt
from repro.configs.base import ModelConfig
from repro.core.asd import asd_sample_batched
from repro.core.schedules import Schedule, sl_geometric
from repro.core.sequential import sequential_sample
from repro.data.pipeline import BlobImages, GMMSequences, RobotReach
from repro.models.diffusion import (
    DenoiserConfig,
    denoiser_init,
    make_sl_model_fn,
    sl_denoiser_loss,
)
from repro.nn.param import unbox
from repro.training.optimizer import adamw, constant_schedule
from repro.training.train_step import make_train_step

CACHE_DIR = os.environ.get("REPRO_BENCH_CACHE", "results/bench_models")
T_MIN, T_MAX = 0.05, 50.0

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_sha() -> str:
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=_REPO_ROOT, timeout=10).stdout.strip()
        if not sha:
            return "unknown"
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], capture_output=True, text=True,
            cwd=_REPO_ROOT, timeout=10).stdout.strip()
        return sha + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def provenance() -> dict:
    """The run-environment block every results/*.json writer stamps: what
    produced this number, on what software, on what hardware shape.
    ``tools/check_bench.py`` ignores it when diffing metric values."""
    return {
        "schema_version": 1,
        "git_sha": _git_sha(),
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "date_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "argv": list(sys.argv),
    }


def write_report(path: str, report: dict) -> dict:
    """Stamp ``provenance`` onto ``report`` and write it to ``path``
    (pretty-printed, trailing newline).  Returns the stamped report."""
    report = dict(report)
    report["provenance"] = provenance()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    return report


def _backbone(n_layers, d_model, n_heads, d_ff):
    return ModelConfig(
        name=f"bench-{n_layers}x{d_model}", family="dense", n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads, d_ff=d_ff,
        vocab_size=1, pos_embed="none", embed_inputs=False,
        compute_dtype="float32", remat=False,
    )


MODELS = {
    # latent-diffusion stand-in (Fig 2 / Tab 1): blob "latents" 64 tokens
    "ldm": dict(bb=_backbone(4, 128, 4, 512),
                data=lambda: BlobImages(grid=8, patch_dim=16, batch=32),
                seq_len=64, d_data=16, d_cond=0, steps=250),
    # pixel-model stand-in (Fig 4 / Tab 2): wider channels, cheaper net
    "pixel": dict(bb=_backbone(3, 96, 4, 384),
                  data=lambda: BlobImages(grid=8, patch_dim=24, batch=32),
                  seq_len=64, d_data=24, d_cond=0, steps=250),
    # diffusion policy (Fig 5 / Tab 3)
    "policy": dict(bb=_backbone(4, 128, 4, 512),
                   data=lambda: RobotReach(horizon=16, batch=128),
                   seq_len=16, d_data=2, d_cond=4, steps=400),
}


def get_trained(kind: str):
    """(params, DenoiserConfig, data) — trains once, then disk-cached."""
    spec = MODELS[kind]
    dc = DenoiserConfig(
        backbone=spec["bb"], seq_len=spec["seq_len"], d_data=spec["d_data"],
        d_cond=spec["d_cond"], time_log=True,
    )
    data = spec["data"]()
    params = unbox(denoiser_init(jax.random.PRNGKey(0), dc))
    cdir = os.path.join(CACHE_DIR, kind)
    if ckpt.latest_step(cdir) is not None:
        params, _ = ckpt.restore(cdir, target=params)
        return params, dc, data

    opt = adamw(constant_schedule(2e-3), weight_decay=0.0)

    def loss_fn(p, batch, rng):
        return (
            sl_denoiser_loss(p, dc, batch["x0"], rng, T_MIN, T_MAX,
                             cond=batch.get("cond")),
            {},
        )

    step = jax.jit(make_train_step(loss_fn, opt))
    opt_state = opt.init(params)
    for s in range(spec["steps"]):
        b = data.batch_at(s)
        batch = {"x0": b[0], "cond": b[1]} if isinstance(b, tuple) else {"x0": b}
        params, opt_state, m = step(params, opt_state, batch, jax.random.PRNGKey(s))
    ckpt.save(cdir, spec["steps"], params)
    return params, dc, data


def bench_schedule(K: int) -> Schedule:
    return sl_geometric(K=K, t_min=T_MIN, t_max=T_MAX)


def final_x(samples: jax.Array) -> np.ndarray:
    """y_T -> x estimate (Law(y_T / T) -> mu as T grows)."""
    return np.asarray(samples) / T_MAX


def run_asd(params, dc, sched, theta, B, key, cond=None, eager=False):
    model_fn_f = lambda c: make_sl_model_fn(params, dc, c)
    if cond is not None:
        fn = lambda y, k, c: __import__("repro.core.asd", fromlist=["asd_sample"]).asd_sample(
            model_fn_f(c), sched, y, k, theta, eager, "counter", False)
        keys = jax.random.split(key, B)
        y0 = jnp.zeros((B, dc.seq_len, dc.d_data))
        return jax.jit(jax.vmap(fn))(y0, keys, cond)
    y0 = jnp.zeros((B, dc.seq_len, dc.d_data))
    return jax.jit(
        lambda y, k: asd_sample_batched(
            model_fn_f(None), sched, y, k, theta, eager, "counter", False)
    )(y0, key)


def run_sequential(params, dc, sched, B, key, cond=None):
    def one(y, k, c=None):
        return sequential_sample(make_sl_model_fn(params, dc, c), sched, y, k)[0]

    y0 = jnp.zeros((B, dc.seq_len, dc.d_data))
    keys = jax.random.split(key, B)
    if cond is not None:
        return jax.jit(jax.vmap(one))(y0, keys, cond)
    return jax.jit(jax.vmap(lambda y, k: one(y, k)))(y0, keys)


def timed(fn, *args, repeats=1):
    out = jax.block_until_ready(fn(*args))  # compile + first run
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = jax.block_until_ready(fn(*args))
    return out, (time.perf_counter() - t0) / repeats


def speedup_row(kind, K, theta, res, wall_asd, wall_seq, B):
    depth = float(np.mean(np.asarray(res.rounds) + np.asarray(res.head_calls)))
    evals = int(np.sum(np.asarray(res.model_evals)))
    return {
        "name": f"{kind}_theta{theta}",
        "K": K,
        "theta": theta,
        "algorithmic_speedup": K / depth,
        "wallclock_speedup": wall_seq / wall_asd if wall_asd else 0.0,
        "parallel_depth": depth,
        "accept_rate": float(np.mean(np.asarray(res.accepts) / np.maximum(np.asarray(res.proposals), 1))),
        "us_per_call": wall_asd * 1e6 / max(evals / B, 1),
    }

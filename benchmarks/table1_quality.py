"""Paper Table 1 (CLIP score invariance): sample quality must not depend on
theta.  Offline proxy: per-theta distribution match of ASD samples against
sequential-DDPM samples — energy distance and moment gaps (no CLIP model in
the container; this tests the same claim more directly)."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks import common

K = 200
THETAS = [2, 4, 6, 8, K]
B = 64


def _energy(x, y, rng, n=20000):
    ix = rng.integers(0, len(x), (n, 2))
    iy = rng.integers(0, len(y), (n, 2))
    dxy = np.linalg.norm(x[ix[:, 0]] - y[iy[:, 0]], axis=1).mean()
    dxx = np.linalg.norm(x[ix[:, 0]] - x[ix[:, 1]], axis=1).mean()
    dyy = np.linalg.norm(y[iy[:, 0]] - y[iy[:, 1]], axis=1).mean()
    return 2 * dxy - dxx - dyy


def run(quick: bool = False):
    params, dc, _ = common.get_trained("ldm")
    thetas = [4, K] if quick else THETAS
    B_ = 32 if quick else B
    sched = common.bench_schedule(K)
    ref = common.final_x(
        common.run_sequential(params, dc, sched, B_, jax.random.PRNGKey(0))
    ).reshape(B_, -1)
    rng = np.random.default_rng(0)
    rows = []
    for theta in thetas:
        res = common.run_asd(params, dc, sched, theta, B_, jax.random.PRNGKey(1))
        xs = common.final_x(res.sample).reshape(B_, -1)
        ed = _energy(ref, xs, rng)
        rows.append({
            "name": f"tab1_quality_theta{theta if theta < K else 'inf'}",
            "energy_distance_vs_ddpm": float(ed),
            "mean_gap": float(np.abs(ref.mean(0) - xs.mean(0)).max()),
            "std_gap": float(np.abs(ref.std(0) - xs.std(0)).max()),
            "us_per_call": 0.0,
            "derived": float(ed),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

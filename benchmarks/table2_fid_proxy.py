"""Paper Table 2 (FID invariance on LSUN Church): Frechet distance between
the Gaussian moment fits of DDPM samples and ASD samples (pixel stand-in).
The paper's claim: ASD-theta has the same FID as DDPM for every theta."""

from __future__ import annotations

import jax
import numpy as np
import scipy.linalg

from benchmarks import common

K = 200
THETAS = [4, 8, K]
B = 64


def frechet(x, y):
    """2-Wasserstein^2 between Gaussian fits (the FID formula)."""
    mu1, mu2 = x.mean(0), y.mean(0)
    s1 = np.cov(x, rowvar=False) + 1e-6 * np.eye(x.shape[1])
    s2 = np.cov(y, rowvar=False) + 1e-6 * np.eye(y.shape[1])
    covmean = scipy.linalg.sqrtm(s1 @ s2).real
    return float(((mu1 - mu2) ** 2).sum() + np.trace(s1 + s2 - 2 * covmean))


def run(quick: bool = False):
    params, dc, data = common.get_trained("pixel")
    thetas = [8] if quick else THETAS
    B_ = 32 if quick else B
    sched = common.bench_schedule(K)
    ref = common.final_x(
        common.run_sequential(params, dc, sched, B_, jax.random.PRNGKey(0))
    ).reshape(B_, -1)
    # also a data reference: FID of DDPM samples vs true data
    x_data = np.asarray(data.batch_at(777)).reshape(data.batch, -1)[:B_]
    rows = [{
        "name": "tab2_fid_ddpm_vs_data",
        "frechet": frechet(ref, x_data),
        "us_per_call": 0.0,
        "derived": frechet(ref, x_data),
    }]
    for theta in thetas:
        res = common.run_asd(params, dc, sched, theta, B_, jax.random.PRNGKey(1))
        xs = common.final_x(res.sample).reshape(B_, -1)
        f = frechet(ref, xs)
        rows.append({
            "name": f"tab2_fid_theta{theta if theta < K else 'inf'}_vs_ddpm",
            "frechet": f,
            "frechet_vs_data": frechet(xs, x_data),
            "us_per_call": 0.0,
            "derived": f,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
